"""Modeled flat vs hierarchical 2D ring step time across fabric ratios.

The topology planner (``ParallelContext.plan(topology=...)``) arbitrates
between the flat bidirectional TokenRing and the hierarchical 2D schedule
(``core/hier2d.py``) by pricing both against a declared link graph.  This
benchmark runs that exact arithmetic — no devices, no compilation — over a
``two_pods(4)`` fabric (P = 8) at inter/intra bandwidth ratios 1x, 4x and
16x, and cross-checks every number against the link-traffic prover: each
candidate's schedule is replayed onto the graph (``analysis.topo_check``)
and must come back finding-free, with the ledger's slowest-wire pass time
equal to the cost model's ``time_s`` under the same bandwidths.

The per-link byte ledgers (``LinkLedger.to_json()``) are embedded in the
output so the numbers are auditable offline: per traversed wire, the exact
forward/backward bytes of one pass and the implied link time.

Results land in ``benchmarks/BENCH_topology.json``.

Run:  PYTHONPATH=src python -m benchmarks.bench_topology
"""

import json
import os

OUT_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "BENCH_topology.json"
)

# The acceptance scenario: MHA with heads not divisible by P, bf16 wire.
B, S, HQ, HKV, D, P = 1, 8192, 4, 4, 128, 8
BPE, TRAVEL = 2, "float32"
RATIOS = (1, 4, 16)


def bench(out_path=OUT_PATH):
    import repro.core  # noqa: F401  (registers the strategies)
    from repro.analysis.comm_audit import AuditDims
    from repro.analysis.topo_check import check_spec_topology
    from repro.core.strategies import (
        get_strategy,
        itemsize,
        resolve_strategy,
        strategy_cost,
    )
    from repro.core.topology import DEFAULT_INTRA_BW, two_pods

    dims = AuditDims(
        B=B, S_loc=S // P, Hq=HQ, Hkv=HKV, D=D,
        bytes_per_elem=BPE, travel_bytes=itemsize(TRAVEL),
    )
    flat_name = resolve_strategy(
        "auto", P=P, B=B, S=S, Hq=HQ, Hkv=HKV, D=D, bytes_per_elem=BPE
    )
    rows = []
    for ratio in RATIOS:
        topo = two_pods(
            P // 2, inter_bw=DEFAULT_INTRA_BW / ratio
        )
        row = {
            "topology": topo.name,
            "inter_over_intra_slowdown": ratio,
            "candidates": {},
        }
        for name in (flat_name, "tokenring2d"):
            desc = get_strategy(name)
            extra = {"n_pods": topo.n_pods} if desc.ring_axes == 2 else {}
            cost = strategy_cost(
                desc, B, S, HQ, HKV, D, P,
                bytes_per_elem=BPE, travel_dtype=TRAVEL, **extra,
            )
            if desc.ring_axes == 2:
                t = cost.time_s(
                    dict(topo.class_bandwidths()), bidir_links=True
                )
            else:
                t = cost.time_s(
                    {"link": topo.bottleneck_bw()}, bidir_links=True
                )
            spec = desc.schedule_spec(P, S_loc=S // P, **extra)
            ledger, findings = check_spec_topology(
                spec, dims, topo, cost=cost, subject=f"{name}@{ratio}x"
            )
            assert findings == [], [f.detail for f in findings]
            row["candidates"][name] = {
                "modeled_step_time_s": t,
                "ledger": ledger.to_json(),
            }
        ts = {n: c["modeled_step_time_s"] for n, c in row["candidates"].items()}
        row["chosen"] = min(ts, key=ts.get)
        row["speedup_2d_over_flat"] = ts[flat_name] / ts["tokenring2d"]
        rows.append(row)
        print(
            f"ratio {ratio:>2}x: {flat_name} {ts[flat_name]:.3e}s  "
            f"tokenring2d {ts['tokenring2d']:.3e}s  -> {row['chosen']}"
        )
    blob = {
        "shape": {
            "B": B, "S": S, "Hq": HQ, "Hkv": HKV, "D": D, "P": P,
            "bytes_per_elem": BPE, "travel_dtype": TRAVEL,
        },
        "flat_candidate": flat_name,
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    return blob


if __name__ == "__main__":
    bench()

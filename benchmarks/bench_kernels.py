"""Kernel micro-benchmarks: flash attention fwd + bwd, merge throughput,
and the backward tile-skip accounting.

(The Pallas path is validated in interpret mode by tests; wall-clock kernel
numbers on CPU are schedule checks, not TPU performance.  The *tile counts*
are exact, though — they evaluate the same position predicate the Pallas
kernels' ``pl.when`` skip does, so the zigzag-causal block-compute ratio
reported here is what the TPU kernels execute.)

``run(json_path=...)`` additionally writes the machine-readable
``BENCH_kernels.json`` consumed by the perf-trajectory tracking:
  * ``fwd`` / ``bwd``: wall time + achieved FLOP/s per config
    (bwd sweeps block sizes x causal/zigzag x GQA),
  * ``tile_skip``: computed/total backward tiles for zigzag-causal vs
    no-skip, window pruning, and the headline ``zigzag_over_noskip`` ratio
    (acceptance: <= ~0.6),
  * ``decode``: paged decode at 4k/32k contexts — fused kernel vs the
    dense-gather path, wall/token plus the exact peak-buffer column (the
    gather's materialized view vs the kernel's context-length-independent
    per-step blocks).
"""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.merge import merge_partials
from repro.core.zigzag import to_zigzag, zigzag_positions
from repro.kernels.ops import backward_tile_counts, flash_attention

DEFAULT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_kernels.json")


def _time(fn, *args, n=5):
    fn(*args)  # compile+warm
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _fwd_flops(B, Sq, Sk, H, D, frac):
    # two matmuls (scores, p@v) over the computed fraction of the matrix
    return 4.0 * B * H * Sq * Sk * D * frac


def _bwd_flops(B, Sq, Sk, H, D, frac):
    # five matmuls (recompute s, dp, dq, dk, dv) over the computed fraction
    return 10.0 * B * H * Sq * Sk * D * frac


def _bench_forward(rng):
    rows, recs = [], []
    for (B, S, H, D), causal in [((1, 2048, 8, 64), True), ((1, 4096, 8, 64), True)]:
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.bfloat16)
        fn = jax.jit(
            lambda q: flash_attention(q, q, q, causal=causal, impl="xla")[0]
        )
        dt = _time(fn, q)
        flops = _fwd_flops(B, S, S, H, D, 0.5 if causal else 1.0)
        print(f"| flash_xla B{B} S{S} H{H} D{D} causal={causal} | "
              f"{dt*1e3:.1f} ms | {flops/dt/1e9:.1f} GFLOP/s |")
        rows.append((f"flash_xla/S{S}", dt * 1e6, f"{flops/dt/1e9:.0f}GFLOPs"))
        recs.append(dict(
            name=f"flash_xla_fwd/S{S}", B=B, S=S, H=H, D=D, causal=causal,
            impl="xla", ms=dt * 1e3, gflops=flops / dt / 1e9,
        ))
    return rows, recs


def _bench_backward(rng):
    """Backward sweep: block sizes x causal/zigzag x GQA (impl=xla on CPU)."""
    rows, recs = [], []
    B, S, D, P = 1, 2048, 64, 4
    pos_zz = jnp.concatenate([zigzag_positions(S, P, j) for j in range(P)])
    for Hq, Hkv in [(8, 8), (8, 2)]:
        q32 = rng.standard_normal((B, S, Hq, D)).astype(np.float32)
        kv32 = rng.standard_normal((B, S, Hkv, D)).astype(np.float32)
        w32 = rng.standard_normal((B, S, Hq, D)).astype(np.float32)
        for layout, causal in [("contig", False), ("contig", True), ("zigzag", True)]:
            if layout == "zigzag":
                q = to_zigzag(jnp.asarray(q32, jnp.bfloat16), P, axis=1)
                kv = to_zigzag(jnp.asarray(kv32, jnp.bfloat16), P, axis=1)
                w = to_zigzag(jnp.asarray(w32, jnp.bfloat16), P, axis=1)
                pos = pos_zz
            else:
                q = jnp.asarray(q32, jnp.bfloat16)
                kv = jnp.asarray(kv32, jnp.bfloat16)
                w = jnp.asarray(w32, jnp.bfloat16)
                pos = jnp.arange(S, dtype=jnp.int32)
            for blk in [128, 256]:
                def loss(q, k, v):
                    out, _ = flash_attention(
                        q, k, v, q_pos=pos, k_pos=pos, causal=causal,
                        impl="xla", block_q=blk, block_k=blk,
                        block_q_bwd=blk, block_k_bwd=blk,
                    )
                    return jnp.sum(out * w)

                fn = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
                dt = _time(fn, q, kv, kv, n=3)
                computed, total = backward_tile_counts(
                    pos[None], pos[None], block_q=blk, block_k=blk,
                    causal=causal,
                )
                frac = computed / total
                flops = _bwd_flops(B, S, S, Hq, D, frac)
                tag = (f"flash_bwd/{layout}{'_causal' if causal else ''}"
                       f"/Hq{Hq}Hkv{Hkv}/blk{blk}")
                print(f"| {tag} | {dt*1e3:.1f} ms | "
                      f"{flops/dt/1e9:.1f} GFLOP/s | tiles {computed}/{total} |")
                rows.append((tag, dt * 1e6, f"{flops/dt/1e9:.0f}GFLOPs"))
                recs.append(dict(
                    name=tag, B=B, S=S, Hq=Hq, Hkv=Hkv, D=D, causal=causal,
                    layout=layout, impl="xla", block_q_bwd=blk, block_k_bwd=blk,
                    ms=dt * 1e3, gflops=flops / dt / 1e9,
                    tiles_computed=computed, tiles_total=total,
                    tile_fraction=frac,
                ))
    return rows, recs


def _bench_decode(rng):
    """Paged decode: fused kernel vs the dense-gather path at 4k/32k.

    Wall/token on CPU compares an interpret-mode Pallas kernel against real
    XLA gathers — a schedule check, not TPU performance (the interpret rows
    use n=1).  The *peak-buffer* column is the structural point and is exact
    from the declared shapes: the gather path materializes the slot's full
    ``(B, W*page_size, Hkv, D)`` K and V views; the fused kernel's largest
    live buffer is one double-buffered page block + the ``(group, D)``
    accumulators (``kernel_buffer_shapes("paged_decode")``), independent of
    context length.
    """
    from repro.analysis.kernel_lint import vmem_estimate
    from repro.kernels.ops import paged_decode_attention
    from repro.serving.kv_cache import PAD_POS

    rows, recs = [], []
    B, Hq, Hkv, D, ps, slack = 2, 8, 2, 64, 128, 8
    for S in (4096, 32768):
        used = -(-S // ps)
        W = used + slack
        n_pages = B * W + 1
        q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
        k_pool = jnp.asarray(
            rng.standard_normal((n_pages, ps, Hkv, D)), jnp.float32
        )
        pos = np.full((n_pages, ps), PAD_POS, np.int32)
        bt = np.full((B, W), n_pages, np.int32)
        pg = 0
        for b in range(B):
            for ip in range(used):
                bt[b, ip] = pg
                pos[pg] = np.arange(ip * ps, (ip + 1) * ps)
                pg += 1
        bt, pos = jnp.asarray(bt), jnp.asarray(pos)
        qp = jnp.full((B, 1), S - 1, jnp.int32)
        lens = jnp.full((B,), S, jnp.int32)
        itemsize = q.dtype.itemsize
        peak = {
            # K and V views, materialized every step, plus the int32 pos view
            "xla": B * W * ps * (2 * Hkv * D * itemsize + 4),
            # double-buffered per-grid-step blocks + scratch, page-count free
            "pallas_interpret": vmem_estimate(
                "paged_decode", block_q=Hq // Hkv, block_k=ps, D=D,
                data_bytes=itemsize,
            ),
        }
        for impl, n in (("xla", 5), ("pallas_interpret", 1)):
            fn = jax.jit(
                lambda q, impl=impl: paged_decode_attention(
                    q, k_pool, k_pool, pos, bt, qp, lengths=lens, impl=impl
                )[0]
            )
            dt = _time(fn, q, n=n)
            path = "gather" if impl == "xla" else "fused"
            tag = f"paged_decode/{path}/S{S}"
            print(f"| {tag} | {dt*1e3:.1f} ms/token | "
                  f"peak buffer {peak[impl]/2**20:.2f} MiB |")
            rows.append((tag, dt * 1e6, f"{peak[impl]/2**20:.2f}MiB"))
            recs.append(dict(
                name=tag, path=path, impl=impl, B=B, S=S, Hq=Hq, Hkv=Hkv,
                D=D, page_size=ps, pages_used=used, table_width=W,
                ms_per_token=dt * 1e3, peak_buffer_bytes=peak[impl],
            ))
    return rows, recs


def _tile_skip_record():
    """Exact backward block-compute counts (the acceptance numbers)."""
    S, P, blk = 8192, 4, 256
    pos_zz = jnp.concatenate([zigzag_positions(S, P, j) for j in range(P)])[None]
    pos_ct = jnp.arange(S, dtype=jnp.int32)[None]
    zz_c, total = backward_tile_counts(
        pos_zz, pos_zz, block_q=blk, block_k=blk, causal=True
    )
    noskip, _ = backward_tile_counts(
        pos_zz, pos_zz, block_q=blk, block_k=blk, causal=False
    )
    win, _ = backward_tile_counts(
        pos_ct, pos_ct, block_q=blk, block_k=blk, causal=True, window=1024
    )
    rec = {
        "S": S, "sp_degree": P, "block": blk,
        "zigzag_causal": {"computed": zz_c, "total": total},
        "no_skip": {"computed": noskip, "total": total},
        "window_1024_contig": {"computed": win, "total": total},
        # headline: zigzag-causal backward block-compute count vs no-skip
        "zigzag_over_noskip": zz_c / noskip,
    }
    print(f"| bwd tile skip S{S} P{P} blk{blk} | zigzag-causal "
          f"{zz_c}/{total} | no-skip {noskip}/{total} | "
          f"ratio {zz_c/noskip:.3f} | window(1024) {win}/{total} |")
    assert zz_c / noskip <= 0.6, (zz_c, noskip)
    return rec


def run(json_path=DEFAULT_JSON):
    rows = []
    rng = np.random.default_rng(0)

    fwd_rows, fwd_recs = _bench_forward(rng)
    rows += fwd_rows
    bwd_rows, bwd_recs = _bench_backward(rng)
    rows += bwd_rows
    dec_rows, dec_recs = _bench_decode(rng)
    rows += dec_rows
    tile_skip = _tile_skip_record()

    # merge throughput (the Update() of the paper)
    shape = (4, 2048, 8, 64)
    o1 = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    l1 = jnp.asarray(rng.standard_normal(shape[:-1]), jnp.float32)
    fn = jax.jit(lambda a, b, c, d: merge_partials(a, b, c, d)[0])
    dt = _time(fn, o1, l1, o1, l1)
    rows.append(("merge_partials/4x2048x8x64", dt * 1e6, ""))
    print(f"| merge_partials {shape} | {dt*1e3:.2f} ms |")

    if json_path:
        record = {
            "backend": jax.default_backend(),
            "fwd": fwd_recs,
            "bwd": bwd_recs,
            "decode": dec_recs,
            "tile_skip": tile_skip,
            "merge_partials_ms": dt * 1e3,
        }
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    run()

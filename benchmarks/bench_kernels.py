"""Kernel micro-benchmarks: flash attention XLA path + merge throughput.

(The Pallas path is validated in interpret mode by tests; wall-clock kernel
numbers on CPU are schedule checks, not TPU performance.)
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.merge import merge_partials
from repro.kernels.ops import flash_attention


def _time(fn, *args, n=5):
    fn(*args)  # compile+warm
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run():
    rows = []
    rng = np.random.default_rng(0)
    for (B, S, H, D), causal in [((1, 2048, 8, 64), True), ((1, 4096, 8, 64), True)]:
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.bfloat16)
        fn = jax.jit(
            lambda q: flash_attention(q, q, q, causal=causal, impl="xla")[0]
        )
        dt = _time(fn, q)
        flops = 4 * B * H * S * S * D * (0.5 if causal else 1.0)
        print(f"| flash_xla B{B} S{S} H{H} D{D} causal={causal} | "
              f"{dt*1e3:.1f} ms | {flops/dt/1e9:.1f} GFLOP/s |")
        rows.append((f"flash_xla/S{S}", dt * 1e6, f"{flops/dt/1e9:.0f}GFLOPs"))

    # merge throughput (the Update() of the paper)
    shape = (4, 2048, 8, 64)
    o1 = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    l1 = jnp.asarray(rng.standard_normal(shape[:-1]), jnp.float32)
    fn = jax.jit(lambda a, b, c, d: merge_partials(a, b, c, d)[0])
    dt = _time(fn, o1, l1, o1, l1)
    rows.append(("merge_partials/4x2048x8x64", dt * 1e6, ""))
    print(f"| merge_partials {shape} | {dt*1e3:.2f} ms |")
    return rows


if __name__ == "__main__":
    run()

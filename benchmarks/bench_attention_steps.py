"""Paper Figure 6 analog: per-step time of the attention schedule.

The paper profiles a 4-GPU A10 node at seq 24 000 (LLaMA2-7B attention) and
finds: Ring-Attention steps are communication-bound (~7.6 ms) while TokenRing
overlaps Q/out transfers with compute (~3.5-4.6 ms per step).

On the TPU target we model per-step time as max(compute, max-direction comm)
— the overlap assumption both the paper and XLA's async collectives make —
using v5e constants, for each strategy.  We also *measure* wall-clock on 4
simulated host devices (schedule correctness, not bandwidth, is what CPU
timing validates; the modeled numbers are the roofline-grade result).

Run directly (sets device count before jax import):
  PYTHONPATH=src python -m benchmarks.bench_attention_steps
"""

import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + os.environ.get("XLA_FLAGS", "")
    )

PEAK_FLOPS = 197e12
LINK_BW = 50e9

# table label -> (registered strategy, cost-model extras); per-step bytes are
# the registered whole-pass comm_cost divided by the (P-1) ring steps, which
# amortizes TokenRing's going-home hop across the pass.
STEP_ROWS = {
    "ring-attention": ("ring", {}),
    "ring-bidir": ("ring_bidir", {}),
    "tokenring": ("tokenring", {"travel_dtype": "bfloat16"}),
}


def modeled_step_times(S=24000, Hq=32, Hkv=32, Dh=128, P=4, b=2):
    """Per-ring-step (compute, comm, step) seconds for each strategy."""
    from repro.core.strategies import get_strategy, strategy_cost

    S_loc = S // P
    # per-step block attention flops: q_loc x kv_loc (causal-balanced ~ x0.5)
    flops = 4 * S_loc * S_loc * Hq * Dh * 0.5
    t_comp = flops / PEAK_FLOPS
    res = {}
    for name, (strategy, extra) in STEP_ROWS.items():
        cost = strategy_cost(
            get_strategy(strategy), 1, S, Hq, Hkv, Dh, P,
            bytes_per_elem=b, **extra,
        )
        t_comm = cost.max_direction / (P - 1) / LINK_BW
        res[name] = (t_comp, t_comm, max(t_comp, t_comm))
    return res


def run():
    rows = []
    print("\n### Figure-6 analog (modeled, v5e): per-step times, llama2-7b attn")
    print("seq 24000, 4 devices, batch 1 | compute ms | comm ms | step ms |")
    for name, (tc, tm, ts) in modeled_step_times().items():
        print(f"| {name} | {tc*1e3:.2f} | {tm*1e3:.2f} | {ts*1e3:.2f} |")
        rows.append((f"fig6_model/{name}", ts * 1e6, f"comm={tm*1e3:.2f}ms"))
    # the paper's observed ratio: ring comm-bound vs tokenring compute-bound
    m = modeled_step_times()
    ratio = m["ring-attention"][2] / m["tokenring"][2]
    print(f"ring/tokenring step-time ratio: {ratio:.2f}x "
          "(paper: 7.6ms vs 3.5-4.6ms ~= 1.7-2.2x)")
    rows.append(("fig6_model/ring_over_tokenring", ratio, "paper ~1.7-2.2x"))
    return rows


def measure_wallclock():
    """CPU wall-clock of the actual schedules on 4 simulated devices."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ParallelContext, sp_attention
    from repro.core.strategies import get_strategy, ineligible_reason, registered_strategies
    from repro.core.zigzag import to_zigzag

    mesh = jax.make_mesh((1, 4), ("data", "model"))
    S, Hq, Dh = 24000 // 5, 32, 64  # scaled for CPU (shape-preserving)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, S, Hq, Dh)), jnp.float32)
    pos = to_zigzag(jnp.arange(S, dtype=jnp.int32)[None, :, None], 4, axis=1)[0, :, 0]
    qz = to_zigzag(q, 4, axis=1)
    rows = []
    runnable = [
        d.name for d in registered_strategies()
        if ineligible_reason(d, Hq=Hq, Hkv=Hq, P=4, layout="zigzag") is None
        and d.ring_axes == 1  # two-axis rings need a (pod, inner) mesh
    ]
    for strategy in runnable:
        pctx = ParallelContext(
            mesh=mesh, data_axis=None, sp_axes=("model",), strategy=strategy,
            impl="xla", block_q=512, block_k=512,
        )
        fn = jax.jit(
            lambda q, p: sp_attention(q, q, q, p, p, pctx=pctx, causal=True)
        )
        fn(qz, pos).block_until_ready()  # compile
        t0 = time.perf_counter()
        n = 3
        for _ in range(n):
            fn(qz, pos).block_until_ready()
        dt = (time.perf_counter() - t0) / n
        print(f"| measured(cpu,4dev) {strategy} | {dt*1e3:.1f} ms/pass |")
        rows.append((f"fig6_cpu/{strategy}", dt * 1e6, "wall"))
    return rows


if __name__ == "__main__":
    run()
    measure_wallclock()

"""Benchmark harness: one section per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV at the end (harness convention).

  * Table 1 analog  — per-scheme communication volumes (bench_comm_volume)
  * Figure 6 analog — per-step times, ring vs tokenring (bench_attention_steps;
    modeled on v5e constants + measured on 4 simulated devices in a
    subprocess so this process keeps a single CPU device)
  * serving — chunked-prefill TTFT / decode tok/s + per-schedule planner
    link bytes (bench_serving)
  * kernel micro-benchmarks (bench_kernels) — also writes the
    machine-readable ``benchmarks/BENCH_kernels.json`` (fwd+bwd wall time,
    achieved FLOP/s, backward tile-skip ratios) so the kernel perf
    trajectory is tracked across PRs
  * roofline summary — from the dry-run artifacts (roofline_report)
"""

from __future__ import annotations

import os
import subprocess
import sys


def main() -> None:
    rows = []

    from benchmarks import bench_comm_volume, bench_kernels

    print("=" * 72)
    print("Table 1 analog: communication volumes")
    rows += bench_comm_volume.run()

    print("=" * 72)
    print("Figure 6 analog: per-step attention times (modeled)")
    from benchmarks import bench_attention_steps

    rows += bench_attention_steps.run()

    # measured wall-clock needs 4 devices -> subprocess
    print("=" * 72)
    print("Figure 6 analog: measured wall-clock (4 simulated devices)")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_attention_steps"],
        capture_output=True, text=True, env=env, timeout=900,
    )
    print(proc.stdout[-2000:])
    if proc.returncode != 0:
        print("measured-bench subprocess failed:", proc.stderr[-1000:])

    # overlap executor bench needs 4 devices -> subprocess; writes
    # benchmarks/BENCH_overlap.json (sequential vs pipelined wall time +
    # modeled overlap + HLO dependency evidence)
    print("=" * 72)
    print("Overlap: sequential vs pipelined executor (4 simulated devices)")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_overlap"],
        capture_output=True, text=True, env=env, timeout=3000,
    )
    print(proc.stdout[-2000:])
    if proc.returncode != 0:
        print("overlap-bench subprocess failed:", proc.stderr[-1000:])

    print("=" * 72)
    print("Serving: chunked prefill TTFT + planner link bytes per schedule")
    from benchmarks import bench_serving

    rows += bench_serving.run()

    print("=" * 72)
    print("Kernel micro-benchmarks (fwd + bwd + tile skip)")
    rows += bench_kernels.run(json_path=bench_kernels.DEFAULT_JSON)

    print("=" * 72)
    print("Roofline summary (from dry-run artifacts)")
    try:
        from benchmarks import roofline_report

        roofline_report.main()
    except Exception as e:  # artifacts may not exist yet
        print("roofline report unavailable:", e)

    print("=" * 72)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()

"""Roofline report: render EXPERIMENTS.md tables from dry-run artifacts."""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "experiments", "artifacts")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(art_dir=ART, mesh="pod", strategy="tokenring"):
    recs = {}
    for f in glob.glob(os.path.join(art_dir, "*.json")):
        r = json.load(open(f))
        if r.get("mesh") == mesh and r.get("strategy") == strategy:
            recs[(r["arch"], r["shape"])] = r
    return recs


def _fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def roofline_table(recs, archs, improvement_notes=None):
    notes = improvement_notes or {}
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPs/HLO | roofline | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in archs:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | skipped | {r['reason']} |")
                continue
            ro = r["roofline"]
            note = notes.get((arch, shape), "")
            lines.append(
                f"| {arch} | {shape} | {_fmt_s(ro['compute_s'])} | "
                f"{_fmt_s(ro['memory_s'])} | {_fmt_s(ro['collective_s'])} | "
                f"{ro['dominant'].replace('_s','')} | "
                f"{ro['useful_flops_ratio']:.2f} | "
                f"{ro['roofline_fraction']*100:.1f}% | {note} |"
            )
    return "\n".join(lines)


def dryrun_table(recs, archs):
    lines = [
        "| arch | shape | kind | params | peak GiB/dev | HLO dot GFLOPs/dev | "
        "collective GB/dev (fwd-dir) | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in archs:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None or r["status"] != "ok":
                continue
            hs = r["hlo_stats_per_device"]
            lines.append(
                f"| {arch} | {shape} | {r['kind']} | "
                f"{r['params_total']/1e9:.2f}B | "
                f"{r['memory']['peak_bytes_per_device']/2**30:.2f} | "
                f"{hs['dot_flops']/1e9:.0f} | "
                f"{hs['link_bytes_fwd']/1e9:.2f} | "
                f"{r['timing']['compile_s']:.0f} |"
            )
    return "\n".join(lines)


# TPU v5e machine balance for the kernel-intensity lines below.
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s


def backward_flop_byte_table(block_sizes=(128, 256, 512), dtype_bytes=2):
    """Per-tile arithmetic intensity of the attention kernels, fwd vs bwd.

    Closed forms from the kernel structure (see docs/kernels.md):
      * forward streams (k, v) per tile while q/acc stay in VMEM:
        4*bq*bk*D flops over 2*bk*D*b bytes  ->  2*bq/b flop/byte.
      * backward streams one of {q,dout} or {k,v} per tile (the other pair is
        grid-resident with the accumulator) plus the row stats; counting both
        kernels' traffic: 10*bq*bk*D flops over 2*(bq+bk)*D*b bytes
        ->  5*bq*bk / (b*(bq+bk)) flop/byte.
    A block size is compute-bound once its intensity clears the machine
    balance; the tile-skip does not change intensity (it removes tiles whole).
    """
    balance = PEAK_FLOPS / HBM_BW
    lines = [
        "| tile (bq=bk) | fwd FLOP/byte | bwd FLOP/byte | machine balance | bwd bound |",
        "|---|---|---|---|---|",
    ]
    for blk in block_sizes:
        fwd = 2.0 * blk / dtype_bytes
        bwd = 5.0 * blk * blk / (dtype_bytes * (blk + blk))
        lines.append(
            f"| {blk} | {fwd:.0f} | {bwd:.0f} | {balance:.0f} | "
            f"{'compute' if bwd >= balance else 'memory'} |"
        )
    return "\n".join(lines)


def paged_decode_bytes_table(
    contexts=(4096, 32768), page_size=128, dtype_bytes=2, Hkv=8, D=128,
    slack_pages=8,
):
    """Per-token HBM traffic of the two paged-decode paths (docs/kernels.md).

    The gather path materializes the slot's full logical view — every
    block-table slot, mapped or not — and then flash re-reads it:
    ``3 * W * page_size * Hkv * D * b`` per token per layer (write + 2
    dtype reads; positions ride along in int32).  The fused kernel streams
    only the mapped pages once: ``2 * used_pages * page_size * Hkv * D * b``.
    ``slack_pages`` models the table headroom a serving slot keeps mapped
    above its current length (the gather pays for it, the kernel does not).
    """
    lines = [
        "| context | pages used | gather view B/token | fused kernel B/token "
        "| ratio |",
        "|---|---|---|---|---|",
    ]
    for S in contexts:
        used = -(-S // page_size)
        W = used + slack_pages
        kv = page_size * Hkv * D * dtype_bytes
        gather = 3 * W * kv
        fused = 2 * used * kv
        lines.append(
            f"| {S} | {used} | {gather/1e6:.1f} MB | {fused/1e6:.1f} MB | "
            f"{gather/fused:.2f}x |"
        )
    return "\n".join(lines)


def main():
    from repro.configs import ASSIGNED

    recs = load()
    print("## Roofline (single-pod 16x16, strategy=tokenring)\n")
    print(roofline_table(recs, ASSIGNED))
    print("\n## Dry-run details\n")
    print(dryrun_table(recs, ASSIGNED))
    print("\n## Attention kernel intensity (fwd vs bwd, bf16)\n")
    print(backward_flop_byte_table())
    print("\n## Paged decode: pages-touched vs materialized-view bytes "
          "(per layer, bf16)\n")
    print(paged_decode_bytes_table())
    recs_mp = load(mesh="multipod")
    ok = sum(1 for r in recs_mp.values() if r["status"] == "ok")
    sk = sum(1 for r in recs_mp.values() if r["status"] == "skipped")
    print(f"\nmulti-pod (2,16,16): {ok} cells compiled, {sk} documented skips")


if __name__ == "__main__":
    main()

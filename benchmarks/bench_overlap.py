"""Sequential vs pipelined executor wall time per SP strategy.

The double-buffered schedule executor (``core/schedule.py``) only moves
dependency edges — every transfer is issued against data in hand at step
entry.  This benchmark runs each ring strategy in both executor modes
(``ParallelContext(overlap=...)``) at S ∈ {2048, 8192} on simulated host
devices and records:

  * measured wall time per pass (best of ``repeats``), sequential vs
    pipelined, and the measured overlap fraction ``1 - pipe/seq``;
  * the planner's modeled times (v5e constants): ``sequential = compute +
    link``, ``pipelined = max(compute, link)``, and the modeled overlap
    fraction — the roofline-grade result;
  * the compiled-HLO dependency evidence (``overlap_report``): scan-body
    permutes blocked by same-step compute, pipelined vs sequential.

On the CPU harness collectives are memcpys with no async engine, so measured
wall times typically show parity — the dependency-graph columns are the
evidence that the pipelined program *can* overlap on hardware with async
collectives, which is exactly what the modeled columns quantify (see
docs/overlap.md).  Results land in ``benchmarks/BENCH_overlap.json``.

Run directly (sets device count before jax import):
  PYTHONPATH=src python -m benchmarks.bench_overlap [--smoke]
"""

import argparse
import json
import os
import time

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + os.environ.get("XLA_FLAGS", "")
    )

PEAK_FLOPS = 197e12  # v5e bf16 per chip
LINK_BW = 50e9  # bytes/s per ICI link direction

STRATEGIES = ["tokenring", "tokenring_faithful", "ring", "ring_bidir"]

OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "BENCH_overlap.json")


def bench(S_list, repeats=3, out_path=OUT_PATH):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ParallelContext, sp_attention
    from repro.core.api import AttnShapes
    from repro.core.zigzag import to_zigzag
    from repro.launch.hlo_analysis import overlap_report

    P_sp = 4
    mesh = jax.make_mesh((1, P_sp), ("data", "model"))
    rng = np.random.default_rng(0)
    results = {}
    for strategy in STRATEGIES:
        results[strategy] = {}
        for S in S_list:
            q = jnp.asarray(rng.standard_normal((1, S, 8, 64)), jnp.float32)
            qz = to_zigzag(q, P_sp, axis=1)
            pos = to_zigzag(
                jnp.arange(S, dtype=jnp.int32)[None, :, None], P_sp, axis=1
            )[0, :, 0]

            row = {}
            for overlap in (True, False):
                pctx = ParallelContext(
                    mesh=mesh, data_axis=None, sp_axes=("model",),
                    strategy=strategy, impl="xla", block_q=256, block_k=256,
                    overlap=overlap,
                )
                fn = jax.jit(
                    lambda q, p, pctx=pctx: sp_attention(
                        q, q, q, p, p, pctx=pctx, causal=True
                    )
                )
                compiled = fn.lower(qz, pos).compile()  # AOT: one compile
                compiled(qz, pos).block_until_ready()  # warm up
                best = float("inf")
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    compiled(qz, pos).block_until_ready()
                    best = min(best, time.perf_counter() - t0)
                rep = overlap_report(compiled.as_text())
                mode = "pipelined" if overlap else "sequential"
                row[f"{mode}_wall_s"] = best
                row[f"{mode}_hlo_body_blocked"] = rep["scan_body_total"][
                    "compute_blocked"
                ]
                row[f"{mode}_hlo_body_permutes"] = rep["scan_body_total"][
                    "permutes"
                ]
                row[f"{mode}_hlo_blocked_total"] = rep["total"]["compute_blocked"]

            seq, pipe = row["sequential_wall_s"], row["pipelined_wall_s"]
            row["measured_overlap_fraction"] = 1.0 - pipe / seq if seq else 0.0

            plan = ParallelContext(
                mesh=mesh, data_axis=None, sp_axes=("model",),
                strategy=strategy, impl="xla",
            ).plan(
                AttnShapes(B=1, Sq=S, Hq=8, Hkv=8, D=64, dtype_bytes=4),
                causal=True,
            )
            row["modeled"] = plan.modeled_times(
                link_bw=LINK_BW, peak_flops=PEAK_FLOPS
            )
            results[strategy][str(S)] = row
            print(
                f"| {strategy:>20} S={S:>5} | seq {seq * 1e3:7.1f} ms | "
                f"pipe {pipe * 1e3:7.1f} ms | measured ovl "
                f"{row['measured_overlap_fraction'] * 100:5.1f}% | modeled ovl "
                f"{row['modeled']['overlap_fraction'] * 100:5.1f}% | "
                f"body blocked {row['pipelined_hlo_body_blocked']}"
                f"/{row['pipelined_hlo_body_permutes']} vs "
                f"{row['sequential_hlo_body_blocked']}"
                f"/{row['sequential_hlo_body_permutes']} |"
            )

            # At compute-dominated sizes pipelining should not lose (it wins
            # ~5-15% even on CPU); wall-clock is load-sensitive though (see
            # the verify skill's concurrent-jobs caveat), so a violation is
            # recorded + warned, never a mid-run abort that would discard
            # every row.  Small sizes are rendezvous-overhead noise — the
            # HLO columns are the result there.  The dependency-graph
            # assertions ARE deterministic and stay hard.
            row["wall_time_regression"] = bool(
                S // P_sp >= 512 and pipe > seq * 1.25
            )
            if row["wall_time_regression"]:
                print(
                    f"WARNING {strategy} S={S}: pipelined {pipe:.3f}s vs "
                    f"sequential {seq:.3f}s — rerun on an idle machine"
                )
            assert row["pipelined_hlo_body_blocked"] == 0, row
            if row["sequential_hlo_body_permutes"]:
                assert (
                    row["sequential_hlo_body_blocked"]
                    == row["sequential_hlo_body_permutes"]
                ), row

    payload = {
        "setup": {
            "devices": P_sp,
            "backend": jax.default_backend(),
            "shapes": {"B": 1, "Hq": 8, "D": 64, "S": list(S_list)},
            "peak_flops": PEAK_FLOPS,
            "link_bw": LINK_BW,
        },
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out_path}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, no JSON rewrite (CI)")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args()
    if args.smoke:
        bench([512], repeats=2, out_path=os.path.join(
            os.environ.get("TMPDIR", "/tmp"), "BENCH_overlap_smoke.json"))
    else:
        bench([2048, 8192], repeats=args.repeats)


if __name__ == "__main__":
    main()

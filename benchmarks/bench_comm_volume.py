"""Paper Table 1 analog: per-step communication of each parallelism scheme.

Analytic per-device bytes for one attention layer's SP schedule, evaluated on
the paper's own setting (LLaMA2-7B attention: H=32, d_head=128, MHA) at
seq 24 000 over 4 devices, plus a GQA column (qwen2-72b: Hq=64, Hkv=8) that
shows where the auto-chooser flips strategy.

The SP rows come straight from the registered ``comm_cost`` models
(``repro.core.strategies``) — the same models the ``"auto"`` planner
arbitrates with — and :func:`closed_form_volumes` keeps the paper's explicit
byte arithmetic alongside as an assertion: if a registered model drifts from
the closed form, ``run()`` (and tests/test_registry.py) fails.

Closed forms (per device, per full pass, b = bytes/elem; P devices;
S_loc = S/P):
  TP (Megatron)      : 2 all-reduces of (S_loc, d) activations per layer
  Ring Attention     : (P-1) * 2*S_loc*Hkv*Dh*b       one direction
  Ring bidir (ours)  : (P-1) *   S_loc*Hkv*Dh*b       per direction
  TokenRing (bidir)  : (P-1) * (S_loc/2)*(2*Hq*Dh+2)*b + going-home hop
  TokenRing faithful : fwd Q stream + sum_i i homeward hop-bytes (torus)
  Ulysses            : 4 all-to-alls of S_loc*H*Dh*b / P per peer
"""

from __future__ import annotations

from repro.core.strategies import resolve_strategy, strategy_cost, get_strategy

LINK_BW = 50e9  # bytes/s/direction (v5e ICI)

# (table label, registered strategy, extra cost-model kwargs)
SP_ROWS = [
    ("ring-attention", "ring", {}),
    ("ring-bidir (ours)", "ring_bidir", {}),
    ("tokenring (bidir, f32 acc)", "tokenring", {"travel_dtype": "float32"}),
    ("tokenring (bidir, bf16 acc wire)", "tokenring", {"travel_dtype": "bfloat16"}),
    ("tokenring (faithful, torus)", "tokenring_faithful", {}),
    ("ulysses (a2a)", "ulysses", {}),
]


def closed_form_volumes(S, Hq, Hkv, Dh, P, b=2):
    """The paper's explicit byte arithmetic, kept as the oracle for the
    registered cost models (fwd-direction bytes, bwd-direction bytes)."""
    S_loc = S // P
    q = S_loc * Hq * Dh * b
    kv = 2 * S_loc * Hkv * Dh * b
    out = S_loc * Hq * Dh * b  # block_out travels at compute dtype here
    lse = S_loc * Hq * 4
    out_f32 = S_loc * Hq * Dh * 4  # accumulator at fp32 (default wire format)
    rows = {}
    rows["ring-attention"] = ((P - 1) * kv, 0.0)
    rows["ring-bidir (ours)"] = ((P - 1) * kv / 2, (P - 1) * kv / 2)
    tr32 = (P - 1) * (q + out_f32 + lse) / 2 + (out_f32 + lse) / 2
    rows["tokenring (bidir, f32 acc)"] = (tr32, tr32)
    tr16 = (P - 1) * (q + out + lse) / 2 + (out + lse) / 2
    rows["tokenring (bidir, bf16 acc wire)"] = (tr16, tr16)
    hop_home = sum(i * (out_f32 + lse) for i in range(1, P))
    rows["tokenring (faithful, torus)"] = ((P - 1) * q, float(hop_home))
    a2a = 4 * S_loc * (Hq + Hkv) / 2 * Dh * b  # q,k,v,out average
    rows["ulysses (a2a)"] = (a2a / 2, a2a / 2)
    return rows


def volumes(S, Hq, Hkv, Dh, P, b=2, d_model=None):
    """Per-direction bytes per scheme: registry cost models + the TP row,
    asserted against :func:`closed_form_volumes`."""
    S_loc = S // P
    d = d_model or Hq * Dh
    rows = {}
    # (fwd-direction bytes, bwd-direction bytes) per device per layer pass
    rows["tensor-parallel"] = (
        2 * S_loc * d * b * (P - 1) / P,
        2 * S_loc * d * b * (P - 1) / P,
    )
    for label, name, extra in SP_ROWS:
        cost = strategy_cost(
            get_strategy(name), 1, S, Hq, Hkv, Dh, P, bytes_per_elem=b, **extra
        )
        rows[label] = (cost.fwd_bytes, cost.bwd_bytes)

    oracle = closed_form_volumes(S, Hq, Hkv, Dh, P, b=b)
    for label, expect in oracle.items():
        got = rows[label]
        assert got == tuple(float(x) for x in expect), (
            f"registered cost model for {label!r} drifted from the paper's "
            f"closed form: {got} != {expect}"
        )
    return rows


def table(title, S, Hq, Hkv, Dh, P):
    print(f"\n### {title}: S={S}, Hq={Hq}, Hkv={Hkv}, Dh={Dh}, P={P}")
    print("| scheme | fwd-dir MB | bwd-dir MB | max-dir time (us) | limitation |")
    print("|---|---|---|---|---|")
    lim = {
        "tensor-parallel": "memory in long context",
        "ring-attention": "one link direction idle",
        "ring-bidir (ours)": "still moves KV",
        "tokenring (bidir, f32 acc)": "moves Q+out (GQA unfriendly)",
        "tokenring (bidir, bf16 acc wire)": "~1e-3 merge rounding",
        "tokenring (faithful, torus)": "O(P^2) hop-bytes off full-mesh",
        "ulysses (a2a)": "SP degree <= head count",
    }
    rows = volumes(S, Hq, Hkv, Dh, P)
    out = []
    for name, (f, bwd) in rows.items():
        t = max(f, bwd) / LINK_BW * 1e6
        print(f"| {name} | {f/1e6:.2f} | {bwd/1e6:.2f} | {t:.1f} | {lim[name]} |")
        out.append((name, t))
    auto = resolve_strategy("auto", S=S, Hq=Hq, Hkv=Hkv, D=Dh, P=P, bytes_per_elem=2)
    print(f"planner 'auto' choice for this setting: **{auto}**")
    return out


def run():
    rows = []
    # Paper's §4.1 setting (MHA): TokenRing halves the max-direction load.
    r1 = table("paper setting (llama2-7b attn, MHA)", 24000, 32, 32, 128, 4)
    # Production GQA: the auto-chooser flips to ring-bidir.
    r2 = table("GQA setting (qwen2-72b)", 32768, 64, 8, 128, 16)
    for name, t in r1:
        rows.append((f"comm_volume/mha4/{name}", t, ""))
    for name, t in r2:
        rows.append((f"comm_volume/gqa16/{name}", t, ""))
    return rows


if __name__ == "__main__":
    run()
